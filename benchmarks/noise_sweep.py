"""Table-7 noise sweep over the INTEGER deployment stacks (paper §4.4).

    PYTHONPATH=src python -m benchmarks.noise_sweep [--dry-run]
    PYTHONPATH=src python -m benchmarks.run --only noise     # full sweep

Replays the paper's five (sigma_w, sigma_a, sigma_MAC) conditions over the
reduced KWS and darknet integer stacks — code-domain weight/activation
noise plus the in-kernel ADC noise epilogue — with N seeded trials per
condition, and records mean/std accuracy and degradation vs the clean
stack to ``BENCH_noise.json`` (merged, so reruns compose with other
sections).

Metric honesty: the stand-in stacks are init-and-folded, not trained
(CPU-scale, see benchmarks/common.py), so "accuracy" here is **agreement
with the clean integer stack's argmax** — the clean prediction is the
ground truth the noisy canary is scored against. That measures exactly
what the deployment question asks (how often does analog noise flip the
served prediction?) without needing a V100-scale checkpoint; the paper's
absolute Table-7 accuracies live in ``run.py --only table7`` on the float
training path. ``logit_dev_mean`` (mean |noisy - clean| logit deviation)
is the continuous companion metric.

The sweep also re-proves, per stack, that the zero-sigma configuration
reproduces today's bit-exactness guarantees: NoiseConfig(0,0,0) == clean,
fused == im2col, batched == unbatched (the acceptance bar for the noise
subsystem leaving the clean path untouched), and measures the paper's
chunked-accumulation mitigation at the two highest conditions.

``--retrain`` (``make bench-retrain`` dry-run-sized, ``run.py --only
retrain`` full) runs the deployment-in-the-loop comparison instead: the
paper's "trained with noise" rows on the INTEGER path, via the deploy-QAT
forward (core/deploy_qat — bit-identical with serving), recorded as the
``retrained`` section of BENCH_noise.json.
"""
from __future__ import annotations

import argparse
import sys

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.core.noise import NoiseConfig, TABLE7_CONDITIONS
from repro.core.quant import QuantConfig
from benchmarks import common

SEED = 0
MITIGATION_CHUNKS = 4


def condition_tag(nc: NoiseConfig) -> str:
    return f"w{nc.sigma_w:.0%}_a{nc.sigma_a:.0%}_mac{nc.sigma_mac:.0%}"


def _stacks(qcfg, *, n_eval: int):
    """(name, apply_fn(x, noise, rng, mac_chunks, impl), eval batch) pairs."""
    from repro.models import darknet, kws
    kws_cfg, kws_ip, dn_cfg, dn_ip = common.reduced_int_models(qcfg)
    rng = np.random.default_rng(SEED)
    x_kws = jax.numpy.asarray(rng.standard_normal(
        (n_eval, kws_cfg.seq_len, kws_cfg.n_mfcc)).astype(np.float32))
    x_dn = jax.numpy.asarray(rng.standard_normal(
        (max(2, n_eval // 4), 16, 16, dn_cfg.in_channels)).astype(np.float32))

    def kws_fn(x, noise, rng_, mac_chunks=1, impl=None):
        return kws.int_apply(kws_ip, x, qcfg, kws_cfg, noise=noise, rng=rng_,
                             mac_chunks=mac_chunks, impl=impl)

    def dn_fn(x, noise, rng_, mac_chunks=1, impl=None):
        return darknet.int_apply(dn_ip, x, qcfg, dn_cfg, noise=noise,
                                 rng=rng_, mac_chunks=mac_chunks, impl=impl)

    return [("kws", kws_fn, x_kws), ("darknet", dn_fn, x_dn)]


def _zero_sigma_parity(name, fn, x):
    """The clean-path guarantees, re-proved with the noise plumbing live."""
    clean = np.asarray(fn(x, None, None))
    zero = np.asarray(fn(x, NoiseConfig(0.0, 0.0, 0.0), jax.random.key(3)))
    fused = np.asarray(fn(x, None, None, 1, "fused"))
    im2col = np.asarray(fn(x, None, None, 1, "im2col"))
    unbatched = np.concatenate(
        [np.asarray(fn(x[i:i + 1], None, None)) for i in range(x.shape[0])])
    out = {
        "zero_sigma_bitexact": bool((zero == clean).all()),
        "fused_vs_im2col_bitexact": bool(
            np.allclose(fused, im2col, rtol=0, atol=1e-5)),
        "batched_vs_unbatched_bitexact": bool(
            np.allclose(unbatched, clean, rtol=0, atol=1e-5)),
    }
    for k, v in out.items():
        print(f"noise,{name}_{k},{v},clean-path guarantee under noise plumbing")
    return out


def _trial_stats(fn, x, clean, labels, nc, *, trials, key, mac_chunks=1):
    accs, devs = [], []
    for t in range(trials):
        y = np.asarray(fn(x, nc, jax.random.fold_in(key, t), mac_chunks))
        accs.append(float((y.argmax(-1) == labels).mean()))
        devs.append(float(np.abs(y - clean).mean()))
    return (float(np.mean(accs)), float(np.std(accs)),
            float(np.mean(devs)), float(np.std(devs)))


# ---------------------------------------------------------------------------
# Deployment-in-the-loop retraining (the paper's "trained with noise" rows,
# on the INTEGER path): finetune the stand-in KWS stack through the
# core/deploy_qat forward — bit-identical with serving — with and without
# the deployed noise field, then score both at the matched sigmas.
# ---------------------------------------------------------------------------

RETRAIN_PRETRAIN_LR = 0.02
RETRAIN_FT_LR = 0.01
RETRAIN_DATA_NOISE = 2.0
RETRAIN_NOISE_DRAWS = 4   # noise draws averaged per step (variance cut)
RETRAIN_BATCH = 64
# full-run sizing, shared by run.py --only retrain and the bare --retrain
# CLI so both entry points write comparably-sized `retrained` rows
RETRAIN_FULL = dict(pretrain_steps=300, ft_steps=200, trials=8, n_eval=128)


def _qat_train(module, params, state, nc_train, *, steps: int, lr: float,
               qcfg, cfg, data, draws: int = 1, seed: int = 0):
    """Train/finetune through the deploy-QAT forward; returns raw params.

    ``nc_train=None`` runs the identical loop (same data order, same
    per-step keys threaded) with the noise field off — the only
    difference between arms is the deployed noise. ``draws`` averages the
    loss over several independent draws of the noise field per step (the
    per-step key folds the draw index), cutting the gradient variance the
    analog noise injects without changing its distribution.

    The loop itself is ``train.trainer.QATFinetune`` — the fleet's
    background retrain job — run to completion, so the bench measures
    the exact engine the control plane hot-swaps from.
    """
    import jax.numpy as jnp
    from repro.core import distill
    from repro.optim import schedules, sgd
    from repro.train.trainer import QATFinetune

    def loss_fn(p, batch, rng):
        xb, yb = batch
        onehot = jax.nn.one_hot(yb, cfg.num_classes)
        total = 0.0
        for d in range(draws if nc_train is not None else 1):
            logits = module.qat_apply(p, state, xb, qcfg, cfg,
                                      noise=nc_train,
                                      rng=jax.random.fold_in(rng, d))
            total = total + jnp.mean(
                distill.softmax_cross_entropy(logits, onehot))
        return total / (draws if nc_train is not None else 1)

    opt = sgd.make(schedules.cosine(lr, steps))
    ft = QATFinetune(loss_fn, params, opt, data=data, steps=steps,
                     batch=RETRAIN_BATCH, seed=seed, clip_norm=1.0)
    return ft.run()


def _stack_names(module, cfg):
    """The code-carrying chain: kws exposes conv_names, darknet
    int_conv_names — one helper so multi-stack callers don't branch."""
    names_fn = getattr(module, "conv_names", None) \
        or module.int_conv_names
    return names_fn(cfg)


def _convert_synced(module, params, state, qcfg, cfg):
    """sync_handoff + convert: deploy-QAT ties scales structurally, so the
    stored inner s_in go stale during training — sync, then the back-map
    (ConvertedStack conversion) validates the repaired contract."""
    from repro.core import integer_inference as ii
    return module.convert_int(
        ii.sync_handoff(params, _stack_names(module, cfg)),
        state, qcfg, cfg)


def _retrain_stack(name):
    """Per-stack retrain descriptor: (module, cfg, eval shape, data maker).

    The kws path keeps the exact seeds/constants the original kws-only
    bench used, so its checked-in rows stay bit-identical; darknet
    derives its own keys (offset per stack index below)."""
    from repro.data import synthetic
    from repro.models import darknet, kws
    if name == "kws":
        cfg = kws.KWSConfig.reduced()

        def make_data(key, n):
            return synthetic.make_mfcc_dataset(
                key, n=n, seq_len=cfg.seq_len, n_mfcc=cfg.n_mfcc,
                num_classes=cfg.num_classes, noise=RETRAIN_DATA_NOISE)
        return kws, cfg, make_data
    if name == "darknet":
        cfg = darknet.DarkNetConfig.reduced()

        def make_data(key, n):
            return synthetic.make_image_dataset(
                key, n=n, shape=(16, 16, cfg.in_channels),
                num_classes=cfg.num_classes)
        return darknet, cfg, make_data
    raise SystemExit(f"unknown retrain stack {name!r} (kws/darknet)")


RETRAIN_STACK_IDX = {"kws": 0, "darknet": 1}  # key-derivation offsets


def _self_agreement(fn, x, nc, *, trials, key):
    """Mean agreement of noisy trials with the SAME stack's clean argmax
    (+ mean |noisy - clean| logit deviation) — _trial_stats against the
    stack's own clean predictions."""
    clean = np.asarray(fn(x, None, None))
    a_m, _, d_m, _ = _trial_stats(fn, x, clean, clean.argmax(-1), nc,
                                  trials=trials, key=key)
    return a_m, d_m


def _retrain_one_stack(stack_name, *, qcfg, pretrain_steps, ft_steps,
                       trials, n_eval, n_train, conditions):
    """One stack's clean-vs-noise-trained comparison; returns
    (parity_bool, rows)."""
    module, cfg, make_data = _retrain_stack(stack_name)
    off = 100 * RETRAIN_STACK_IDX[stack_name]  # kws (off=0): legacy keys
    kd1, kd2 = jax.random.split(jax.random.key(SEED + 5 + off))
    data = make_data(kd1, n_train)
    x_eval, _ = make_data(kd2, n_eval)

    # bit-parity re-proof: the QAT forward IS the deployed integer path
    params0, state, ip0 = common.trained_int_params(
        module, cfg, _stack_names(module, cfg), qcfg)
    rng_par = jax.random.key(SEED + 9 + off)
    qat = np.asarray(module.qat_apply(params0, state, x_eval, qcfg, cfg,
                                      noise=conditions[-1], rng=rng_par))
    intp = np.asarray(module.int_apply(ip0, x_eval, qcfg, cfg,
                                       noise=conditions[-1], rng=rng_par))
    parity = bool((qat == intp).all())
    print(f"retrain,{stack_name}_qat_forward_bit_parity,{parity},"
          "qat_apply == int_apply under the deployed noise field")

    tkw = dict(qcfg=qcfg, cfg=cfg, data=data)
    pre = _qat_train(module, params0, state, None, steps=pretrain_steps,
                     lr=RETRAIN_PRETRAIN_LR, **tkw)
    clean_params = _qat_train(module, pre, state, None, steps=ft_steps,
                              lr=RETRAIN_FT_LR, seed=7, **tkw)
    clean_ip = _convert_synced(module, clean_params, state, qcfg, cfg)

    def fn(ip):
        return lambda x, n_, r_, mac_chunks=1: module.int_apply(
            ip, x, qcfg, cfg, noise=n_, rng=r_, mac_chunks=mac_chunks)

    rows = []
    for ci, nc in enumerate(conditions):
        noisy_params = _qat_train(module, pre, state, nc, steps=ft_steps,
                                  lr=RETRAIN_FT_LR, seed=7,
                                  draws=RETRAIN_NOISE_DRAWS, **tkw)
        noisy_ip = _convert_synced(module, noisy_params, state, qcfg, cfg)
        key = jax.random.fold_in(jax.random.key(SEED + 23 + off), ci)
        a_clean, d_clean = _self_agreement(fn(clean_ip), x_eval, nc,
                                           trials=trials, key=key)
        a_noise, d_noise = _self_agreement(fn(noisy_ip), x_eval, nc,
                                           trials=trials, key=key)
        rows.append(dict(
            stack=stack_name, condition=condition_tag(nc),
            sigma_w=nc.sigma_w, sigma_a=nc.sigma_a, sigma_mac=nc.sigma_mac,
            pretrain_steps=pretrain_steps, ft_steps=ft_steps,
            noise_draws=RETRAIN_NOISE_DRAWS, trials=trials,
            n_eval=int(x_eval.shape[0]),
            agreement_clean_trained=round(a_clean, 4),
            agreement_noise_trained=round(a_noise, 4),
            retrain_gain=round(a_noise - a_clean, 4),
            logit_dev_clean_trained=round(d_clean, 5),
            logit_dev_noise_trained=round(d_noise, 5),
            noise_trained_no_worse=bool(a_noise >= a_clean)))
        print(f"retrain,{stack_name}_{condition_tag(nc)},{a_noise:.4f},"
              f"noise-trained agreement vs {a_clean:.4f} clean-trained "
              f"({ft_steps} deploy-QAT finetune steps)")
    return parity, rows


def run_retrain(*, pretrain_steps: int, ft_steps: int, trials: int,
                n_eval: int, n_train: int = 512, conditions=None,
                stacks=("kws",), out_path: str = "BENCH_noise.json"):
    """Clean-trained vs noise-trained Table-7 agreement at matched sigmas.

    The paper's protocol (§4.4: retrain an already-trained net with the
    noise it will see): pretrain the reduced stack clean through the
    deploy-QAT forward (shared checkpoint), then run two matched finetune
    arms per condition — one clean, one against the DEPLOYED noise field
    (bit-identical with serving, multi-draw loss averaging) — convert both
    back through the ConvertedStack round-trip and replay the noisy
    integer stack. Acceptance: at the two highest conditions, the
    noise-trained arm's clean-agreement must be >= the clean-trained
    baseline's.

    ``stacks`` selects kws and/or darknet; rows MERGE by stack into the
    existing ``retrained`` section, so a darknet-only (dry-run-sized) run
    composes with the checked-in full-size kws rows instead of clobbering
    them.
    """
    import json
    import os
    qcfg = QuantConfig(2, 4, 4, fq=True)
    conditions = conditions or TABLE7_CONDITIONS[-2:]
    parity_by_stack, rows = {}, []
    for stack_name in stacks:
        parity, srows = _retrain_one_stack(
            stack_name, qcfg=qcfg, pretrain_steps=pretrain_steps,
            ft_steps=ft_steps, trials=trials, n_eval=n_eval,
            n_train=n_train, conditions=conditions)
        parity_by_stack[stack_name] = parity
        rows.extend(srows)

    # merge by stack: keep other stacks' existing rows (and parity flags)
    if os.path.exists(out_path):
        try:
            with open(out_path) as f:
                old = json.load(f).get("retrained", {})
        except (OSError, ValueError):
            old = {}
        rows = [r for r in old.get("rows", [])
                if r.get("stack") not in stacks] + rows
        for k, v in old.get("qat_forward_bit_parity_by_stack", {}).items():
            parity_by_stack.setdefault(k, v)
        # pre-multi-stack artifacts recorded only the scalar kws flag
        old_scalar = old.get("qat_forward_bit_parity")
        if old_scalar is not None:
            for r in rows:
                parity_by_stack.setdefault(r["stack"], old_scalar)

    doc = {"retrained": {
        "benchmark": "table7_deployment_in_the_loop_retraining",
        "backend": jax.default_backend(),
        "seed": SEED,
        "qcfg": qcfg.label(),
        "qat_forward_bit_parity": all(parity_by_stack.values()),
        "qat_forward_bit_parity_by_stack": parity_by_stack,
        "metric_note": (
            "agreement = noisy trials vs the SAME retrained stack's clean "
            "integer argmax at the matched (trained) sigma; shared clean "
            "pretrain, then matched finetune arms through the deploy-QAT "
            "forward (core/deploy_qat: forward bit-identical with the "
            "deployed integer path, backward float FQ/STE) differing only "
            "in the noise field; multi-draw loss averaging cuts the "
            "gradient variance of the injected noise"),
        "rows": rows,
    }}
    common.merge_bench_json(out_path, doc)
    print(f"retrain,artifact,{out_path},written")
    return doc


def bench_retrain():
    """benchmarks/run.py --only retrain: the full retrain comparison."""
    print("# Table 7 (integer) — deployment-in-the-loop retraining")
    run_retrain(**RETRAIN_FULL)


def run_sweep(*, trials: int, n_eval: int, out_path: str = "BENCH_noise.json"):
    qcfg = QuantConfig(2, 4, 4, fq=True)
    backend = jax.default_backend()
    rows, parity, mitigation = [], {}, []
    for si, (name, fn, x) in enumerate(_stacks(qcfg, n_eval=n_eval)):
        parity[name] = _zero_sigma_parity(name, fn, x)
        clean = np.asarray(fn(x, None, None))
        labels = clean.argmax(-1)
        base = jax.random.key(SEED + 17 * si)
        for ci, nc in enumerate(TABLE7_CONDITIONS):
            a_m, a_s, d_m, d_s = _trial_stats(
                fn, x, clean, labels, nc, trials=trials,
                key=jax.random.fold_in(base, ci))
            rows.append(dict(
                stack=name, condition=condition_tag(nc),
                sigma_w=nc.sigma_w, sigma_a=nc.sigma_a,
                sigma_mac=nc.sigma_mac, trials=trials,
                n_eval=int(x.shape[0]), accuracy_mean=round(a_m, 4),
                accuracy_std=round(a_s, 4),
                degradation_vs_clean=round(1.0 - a_m, 4),
                logit_dev_mean=round(d_m, 5), logit_dev_std=round(d_s, 5)))
            print(f"noise,{name}_{condition_tag(nc)},{a_m:.4f},"
                  f"agreement-with-clean over {trials} trials "
                  f"(mean|dlogit| {d_m:.4f})")
        # chunked-accumulation mitigation at the two highest conditions
        for ci, nc in list(enumerate(TABLE7_CONDITIONS))[-2:]:
            key = jax.random.fold_in(base, 100 + ci)
            un = _trial_stats(fn, x, clean, labels, nc, trials=trials,
                              key=key, mac_chunks=1)
            ch = _trial_stats(fn, x, clean, labels, nc, trials=trials,
                              key=key, mac_chunks=MITIGATION_CHUNKS)
            mitigation.append(dict(
                stack=name, condition=condition_tag(nc),
                mac_chunks=MITIGATION_CHUNKS, trials=trials,
                accuracy_unchunked=round(un[0], 4),
                accuracy_chunked=round(ch[0], 4),
                logit_dev_unchunked=round(un[2], 5),
                logit_dev_chunked=round(ch[2], 5),
                mitigation_helps=bool(ch[2] <= un[2])))
            print(f"noise,{name}_{condition_tag(nc)}_chunks"
                  f"{MITIGATION_CHUNKS},{ch[0]:.4f},vs {un[0]:.4f} unchunked "
                  f"(dev {ch[2]:.4f} vs {un[2]:.4f})")

    doc = {
        "benchmark": "table7_noise_integer_stacks",
        "backend": backend,
        "seed": SEED,
        "qcfg": qcfg.label(),
        "metric_note": (
            "accuracy = agreement with the clean integer stack's argmax "
            "(stand-in stacks are init-and-folded, not trained — the "
            "deployment question is how often analog noise flips the "
            "served prediction); logit_dev_* is mean |noisy - clean|. "
            "sigma_* are fractions of one LSB, per paper §4.4"),
        "mitigation_note": (
            f"mac_chunks={MITIGATION_CHUNKS} splits the MAC readout into "
            "per-chunk ADC conversions at 1/K dynamic range: effective "
            "accumulator noise std drops by sqrt(K)"),
        "conditions": [condition_tag(nc) for nc in TABLE7_CONDITIONS],
        "zero_sigma_parity": parity,
        "rows": rows,
        "mitigation": mitigation,
    }
    common.merge_bench_json(out_path, doc)
    print(f"noise,artifact,{out_path},written")
    return doc


def bench_noise():
    """benchmarks/run.py --only noise: the full five-condition sweep."""
    print("# Table 7 (integer) — analog-noise sweep over the int8 stacks")
    run_sweep(trials=5, n_eval=32)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny sweep (2 trials, small eval batch) — the "
                         "make bench-noise / bench-retrain targets")
    ap.add_argument("--trials", type=int, default=None)
    ap.add_argument("--retrain", action="store_true",
                    help="run the deployment-in-the-loop retraining "
                         "comparison instead of the inference sweep")
    ap.add_argument("--stacks", default="kws",
                    help="comma-separated retrain stacks (kws,darknet); "
                         "rows merge by stack into BENCH_noise.json")
    args = ap.parse_args(argv)
    if args.retrain:
        stacks = tuple(s for s in args.stacks.split(",") if s)
        print("# Table 7 (integer) — deployment-in-the-loop retraining"
              + (" [dry-run]" if args.dry_run else ""))
        if args.dry_run:
            run_retrain(pretrain_steps=60, ft_steps=40,
                        trials=args.trials or 2, n_eval=32, n_train=128,
                        stacks=stacks)
        else:
            run_retrain(**{**RETRAIN_FULL,
                           "trials": args.trials or RETRAIN_FULL["trials"]},
                        stacks=stacks)
        return 0
    trials = args.trials or (2 if args.dry_run else 5)
    n_eval = 8 if args.dry_run else 32
    print("# Table 7 (integer) — analog-noise sweep"
          + (" [dry-run]" if args.dry_run else ""))
    run_sweep(trials=trials, n_eval=n_eval)
    return 0


if __name__ == "__main__":
    sys.exit(main())
