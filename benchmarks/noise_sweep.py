"""Table-7 noise sweep over the INTEGER deployment stacks (paper §4.4).

    PYTHONPATH=src python -m benchmarks.noise_sweep [--dry-run]
    PYTHONPATH=src python -m benchmarks.run --only noise     # full sweep

Replays the paper's five (sigma_w, sigma_a, sigma_MAC) conditions over the
reduced KWS and darknet integer stacks — code-domain weight/activation
noise plus the in-kernel ADC noise epilogue — with N seeded trials per
condition, and records mean/std accuracy and degradation vs the clean
stack to ``BENCH_noise.json`` (merged, so reruns compose with other
sections).

Metric honesty: the stand-in stacks are init-and-folded, not trained
(CPU-scale, see benchmarks/common.py), so "accuracy" here is **agreement
with the clean integer stack's argmax** — the clean prediction is the
ground truth the noisy canary is scored against. That measures exactly
what the deployment question asks (how often does analog noise flip the
served prediction?) without needing a V100-scale checkpoint; the paper's
absolute Table-7 accuracies live in ``run.py --only table7`` on the float
training path. ``logit_dev_mean`` (mean |noisy - clean| logit deviation)
is the continuous companion metric.

The sweep also re-proves, per stack, that the zero-sigma configuration
reproduces today's bit-exactness guarantees: NoiseConfig(0,0,0) == clean,
fused == im2col, batched == unbatched (the acceptance bar for the noise
subsystem leaving the clean path untouched), and measures the paper's
chunked-accumulation mitigation at the two highest conditions.
"""
from __future__ import annotations

import argparse
import sys

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.core.noise import NoiseConfig, TABLE7_CONDITIONS
from repro.core.quant import QuantConfig
from benchmarks import common

SEED = 0
MITIGATION_CHUNKS = 4


def condition_tag(nc: NoiseConfig) -> str:
    return f"w{nc.sigma_w:.0%}_a{nc.sigma_a:.0%}_mac{nc.sigma_mac:.0%}"


def _stacks(qcfg, *, n_eval: int):
    """(name, apply_fn(x, noise, rng, mac_chunks, impl), eval batch) pairs."""
    from repro.models import darknet, kws
    kws_cfg, kws_ip, dn_cfg, dn_ip = common.reduced_int_models(qcfg)
    rng = np.random.default_rng(SEED)
    x_kws = jax.numpy.asarray(rng.standard_normal(
        (n_eval, kws_cfg.seq_len, kws_cfg.n_mfcc)).astype(np.float32))
    x_dn = jax.numpy.asarray(rng.standard_normal(
        (max(2, n_eval // 4), 16, 16, dn_cfg.in_channels)).astype(np.float32))

    def kws_fn(x, noise, rng_, mac_chunks=1, impl=None):
        return kws.int_apply(kws_ip, x, qcfg, kws_cfg, noise=noise, rng=rng_,
                             mac_chunks=mac_chunks, impl=impl)

    def dn_fn(x, noise, rng_, mac_chunks=1, impl=None):
        return darknet.int_apply(dn_ip, x, qcfg, dn_cfg, noise=noise,
                                 rng=rng_, mac_chunks=mac_chunks, impl=impl)

    return [("kws", kws_fn, x_kws), ("darknet", dn_fn, x_dn)]


def _zero_sigma_parity(name, fn, x):
    """The clean-path guarantees, re-proved with the noise plumbing live."""
    clean = np.asarray(fn(x, None, None))
    zero = np.asarray(fn(x, NoiseConfig(0.0, 0.0, 0.0), jax.random.key(3)))
    fused = np.asarray(fn(x, None, None, 1, "fused"))
    im2col = np.asarray(fn(x, None, None, 1, "im2col"))
    unbatched = np.concatenate(
        [np.asarray(fn(x[i:i + 1], None, None)) for i in range(x.shape[0])])
    out = {
        "zero_sigma_bitexact": bool((zero == clean).all()),
        "fused_vs_im2col_bitexact": bool(
            np.allclose(fused, im2col, rtol=0, atol=1e-5)),
        "batched_vs_unbatched_bitexact": bool(
            np.allclose(unbatched, clean, rtol=0, atol=1e-5)),
    }
    for k, v in out.items():
        print(f"noise,{name}_{k},{v},clean-path guarantee under noise plumbing")
    return out


def _trial_stats(fn, x, clean, labels, nc, *, trials, key, mac_chunks=1):
    accs, devs = [], []
    for t in range(trials):
        y = np.asarray(fn(x, nc, jax.random.fold_in(key, t), mac_chunks))
        accs.append(float((y.argmax(-1) == labels).mean()))
        devs.append(float(np.abs(y - clean).mean()))
    return (float(np.mean(accs)), float(np.std(accs)),
            float(np.mean(devs)), float(np.std(devs)))


def run_sweep(*, trials: int, n_eval: int, out_path: str = "BENCH_noise.json"):
    qcfg = QuantConfig(2, 4, 4, fq=True)
    backend = jax.default_backend()
    rows, parity, mitigation = [], {}, []
    for si, (name, fn, x) in enumerate(_stacks(qcfg, n_eval=n_eval)):
        parity[name] = _zero_sigma_parity(name, fn, x)
        clean = np.asarray(fn(x, None, None))
        labels = clean.argmax(-1)
        base = jax.random.key(SEED + 17 * si)
        for ci, nc in enumerate(TABLE7_CONDITIONS):
            a_m, a_s, d_m, d_s = _trial_stats(
                fn, x, clean, labels, nc, trials=trials,
                key=jax.random.fold_in(base, ci))
            rows.append(dict(
                stack=name, condition=condition_tag(nc),
                sigma_w=nc.sigma_w, sigma_a=nc.sigma_a,
                sigma_mac=nc.sigma_mac, trials=trials,
                n_eval=int(x.shape[0]), accuracy_mean=round(a_m, 4),
                accuracy_std=round(a_s, 4),
                degradation_vs_clean=round(1.0 - a_m, 4),
                logit_dev_mean=round(d_m, 5), logit_dev_std=round(d_s, 5)))
            print(f"noise,{name}_{condition_tag(nc)},{a_m:.4f},"
                  f"agreement-with-clean over {trials} trials "
                  f"(mean|dlogit| {d_m:.4f})")
        # chunked-accumulation mitigation at the two highest conditions
        for ci, nc in list(enumerate(TABLE7_CONDITIONS))[-2:]:
            key = jax.random.fold_in(base, 100 + ci)
            un = _trial_stats(fn, x, clean, labels, nc, trials=trials,
                              key=key, mac_chunks=1)
            ch = _trial_stats(fn, x, clean, labels, nc, trials=trials,
                              key=key, mac_chunks=MITIGATION_CHUNKS)
            mitigation.append(dict(
                stack=name, condition=condition_tag(nc),
                mac_chunks=MITIGATION_CHUNKS, trials=trials,
                accuracy_unchunked=round(un[0], 4),
                accuracy_chunked=round(ch[0], 4),
                logit_dev_unchunked=round(un[2], 5),
                logit_dev_chunked=round(ch[2], 5),
                mitigation_helps=bool(ch[2] <= un[2])))
            print(f"noise,{name}_{condition_tag(nc)}_chunks"
                  f"{MITIGATION_CHUNKS},{ch[0]:.4f},vs {un[0]:.4f} unchunked "
                  f"(dev {ch[2]:.4f} vs {un[2]:.4f})")

    doc = {
        "benchmark": "table7_noise_integer_stacks",
        "backend": backend,
        "seed": SEED,
        "qcfg": qcfg.label(),
        "metric_note": (
            "accuracy = agreement with the clean integer stack's argmax "
            "(stand-in stacks are init-and-folded, not trained — the "
            "deployment question is how often analog noise flips the "
            "served prediction); logit_dev_* is mean |noisy - clean|. "
            "sigma_* are fractions of one LSB, per paper §4.4"),
        "mitigation_note": (
            f"mac_chunks={MITIGATION_CHUNKS} splits the MAC readout into "
            "per-chunk ADC conversions at 1/K dynamic range: effective "
            "accumulator noise std drops by sqrt(K)"),
        "conditions": [condition_tag(nc) for nc in TABLE7_CONDITIONS],
        "zero_sigma_parity": parity,
        "rows": rows,
        "mitigation": mitigation,
    }
    common.merge_bench_json(out_path, doc)
    print(f"noise,artifact,{out_path},written")
    return doc


def bench_noise():
    """benchmarks/run.py --only noise: the full five-condition sweep."""
    print("# Table 7 (integer) — analog-noise sweep over the int8 stacks")
    run_sweep(trials=5, n_eval=32)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny sweep (2 trials, small eval batch) — the "
                         "make bench-noise target")
    ap.add_argument("--trials", type=int, default=None)
    args = ap.parse_args(argv)
    trials = args.trials or (2 if args.dry_run else 5)
    n_eval = 8 if args.dry_run else 32
    print("# Table 7 (integer) — analog-noise sweep"
          + (" [dry-run]" if args.dry_run else ""))
    run_sweep(trials=trials, n_eval=n_eval)
    return 0


if __name__ == "__main__":
    sys.exit(main())
