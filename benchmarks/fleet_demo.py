"""Fleet incident demo: canary breach -> auto-retrain -> hot-swap,
under injected device faults, with bit-exact replay (ISSUE 7 acceptance).

    PYTHONPATH=src python -m benchmarks.fleet_demo [--dry-run]
    PYTHONPATH=src python -m benchmarks.run --only fleet      # full size

One seeded end-to-end incident on the REAL integer stacks:

* a two-model registry (reduced kws + darknet ``ConvertedStack``s)
  serves behind per-model ``CNNBatcher``s with SLOs, the device boundary
  wrapped in an active ``FaultPlan`` (flush failures, stuck in-flight
  results, canary corruption) the whole time;
* at a fixed tick the kws deployment drifts to the highest Table-7
  noise condition — the noise canary's rolling median breaches the
  clean-agreement baseline;
* the runtime runs a background deploy-QAT finetune (``QATFinetuneJob``,
  a few steps per scheduler tick, serving never stops), then
  ``rederive()`` + ``swap_apply_fn`` hot-swaps the retrained stack;
* every submitted request is served exactly once within its SLO
  deadline or shed with a structured error — audited, not assumed;
* ``trace.replay`` re-drives the recorded schedule through a freshly
  built fleet and must reproduce every event — output digests, fault
  draws, canary agreements, retrain losses — bit-exactly.

Results go to ``BENCH_fleet.json``. The dry-run sizing is what
``make bench-fleet`` and the fleet-marked test run; ``run.py --only
fleet`` uses the full retrain budget.
"""
from __future__ import annotations

import argparse
import sys

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.core.noise import TABLE7_CONDITIONS
from repro.core.quant import QuantConfig
from repro.serve import trace as trace_mod
from repro.serve.faults import FaultPlan
from repro.serve.fleet import (FleetRuntime, ModelSLO, QATFinetuneJob,
                               RequestSpec)
from benchmarks import common

SEED = 0

# the incident schedule (ticks are the only clock)
PRE_DRIFT_TICKS = 8          # clean era: baseline anchors here
DRIFT_CONDITION = TABLE7_CONDITIONS[-1]   # w30%/a30%/mac150%
POST_DRIFT_TICKS = 40        # breach -> retrain -> swap -> re-baseline
KWS_PER_TICK = 2             # request arrivals
DN_EVERY = 3                 # darknet request every Nth tick

PLAN = FaultPlan(seed=SEED + 13, p_flush_fail=0.15, p_stuck=0.2,
                 max_stuck_ticks=2, p_canary_corrupt=0.08,
                 max_retries=3, backoff_ticks=1)
# drop threshold 0.25: the drift condition costs ~0.5 agreement (breach
# is unambiguous) while the post-swap noisy-canary medians wobble with
# std ~0.06 — 0.15 sat at ~2.4 sigma and re-breached on sampling noise
KWS_SLO = ModelSLO(deadline_ticks=8, max_agreement_drop=0.25,
                   canary_every=1, canary_window=4, baseline_obs=3,
                   retrain_steps_per_tick=10)
DN_SLO = ModelSLO(deadline_ticks=8, max_agreement_drop=0.5,
                  canary_every=2, canary_window=4, baseline_obs=2)

# sizing: dry-run finishes the background job in a few ticks; the full
# run uses the Table-7 retrain bench's pretrain/finetune budgets
SIZES = {
    "dry": dict(pre_steps=60, ft_steps=30, n_train=128, ft_batch=32),
    "full": dict(pre_steps=300, ft_steps=200, n_train=512, ft_batch=64),
}

# pretrained-kws cache: build_fleet runs twice per demo (live + replay)
# and the pretrain is deterministic, so recomputing it only burns time
_PRETRAINED = {}


def _pretrained_kws(size_name):
    """The fleet's deployed kws: pretrained on the finetune data (the
    retrain bench's recipe) so the breach-time finetune starts from a
    fitted model — finetuning the init-and-fold stand-in instead walks
    it toward chance loss and *shrinks* logit margins, which reads as a
    post-swap canary regression."""
    hit = _PRETRAINED.get(size_name)
    if hit is not None:
        return hit
    from repro.data import synthetic
    from repro.models import kws
    from benchmarks import noise_sweep
    size = SIZES[size_name]
    qcfg = QuantConfig(2, 4, 4, fq=True)
    cfg = kws.KWSConfig.reduced()
    params0, state, _ = common.trained_int_params(
        kws, cfg, kws.conv_names(cfg), qcfg)
    kd1, kd2 = jax.random.split(jax.random.key(SEED + 5))

    def make_data(key, n):
        return synthetic.make_mfcc_dataset(
            key, n=n, seq_len=cfg.seq_len, n_mfcc=cfg.n_mfcc,
            num_classes=cfg.num_classes,
            noise=noise_sweep.RETRAIN_DATA_NOISE)
    data = make_data(kd1, size["n_train"])
    # canary probe: held-out samples from the DATA distribution. An
    # off-manifold probe (random normal) collapses to one predicted
    # class, so every noise draw flips the whole batch together and the
    # agreement becomes a coin flip no probe size can stabilize.
    probe, _ = make_data(kd2, 64)
    pre = noise_sweep._qat_train(
        kws, params0, state, None, steps=size["pre_steps"],
        lr=noise_sweep.RETRAIN_PRETRAIN_LR, qcfg=qcfg, cfg=cfg, data=data)
    stack = noise_sweep._convert_synced(kws, pre, state, qcfg, cfg)
    out = (cfg, qcfg, pre, state, data, np.asarray(probe), stack)
    _PRETRAINED[size_name] = out
    return out


def build_fleet(config, trace):
    """Rebuild the runtime exactly as recorded — shared by the live run
    and ``trace.replay`` (the soundness requirement: same builders, same
    order, same seeds; everything else comes from the trace)."""
    from repro.models import darknet, kws
    # re-emit the config event so the fresh trace lines up event-for-event
    # with the recording (replay compares from event 0)
    trace.emit("config", **{k: v for k, v in config.items() if k != "e"})
    size = SIZES[config["size"]]
    kws_cfg, qcfg, kws_pre, kws_state, data, kws_probe, kws_ip = \
        _pretrained_kws(config["size"])
    _, _, dn_cfg, dn_ip = common.reduced_int_models(qcfg)

    rng = np.random.default_rng(SEED)
    dn_probe = rng.standard_normal(
        (8, 16, 16, dn_cfg.in_channels)).astype(np.float32)

    def kws_factory(stack, condition):
        # the pretrained float params the CURRENT stack was derived
        # from; the job finetunes them against the breached condition
        # and hands back (layer_params, extras) for stack.rederive
        return QATFinetuneJob(
            kws, kws_pre, kws_state, kws_cfg, qcfg, condition,
            data=data, steps=size["ft_steps"], lr=0.01,
            batch=size["ft_batch"], draws=4, seed=7)

    fleet = FleetRuntime(fault_plan=PLAN, trace=trace)
    fleet.register(
        "kws", kws_ip, lambda s: kws.int_serve_fn(s, qcfg, kws_cfg),
        slo=KWS_SLO, probe=kws_probe, canary_seed=SEED + 31,
        finetune_factory=kws_factory,
        batcher_kw=dict(max_batch=8, max_wait_ticks=1,
                        dispatch_ahead=True, max_inflight=2))
    fleet.register(
        "darknet", dn_ip, lambda s: darknet.int_serve_fn(s, qcfg, dn_cfg),
        slo=DN_SLO, probe=dn_probe, canary_seed=SEED + 47,
        batcher_kw=dict(max_batch=4, max_wait_ticks=1,
                        dispatch_ahead=True, max_inflight=2))
    fleet.shapes = {
        "kws": (kws_cfg.seq_len, kws_cfg.n_mfcc),
        "darknet": (16, 16, dn_cfg.in_channels),
    }
    return fleet


def drive(fleet):
    """The recorded schedule: steady traffic, drift at a fixed tick."""
    rid = {"kws": 0, "darknet": 10_000}

    def arrive(model, n):
        fleet.submit(model, [
            RequestSpec(rid=rid[model] + i, seed=SEED + 3,
                        shape=fleet.shapes[model])
            for i in range(n)])
        rid[model] += n

    for t in range(PRE_DRIFT_TICKS):
        arrive("kws", KWS_PER_TICK)
        if t % DN_EVERY == 0:
            arrive("darknet", 1)
        fleet.tick()
    fleet.set_condition("kws", DRIFT_CONDITION)
    for t in range(POST_DRIFT_TICKS):
        arrive("kws", KWS_PER_TICK)
        if t % DN_EVERY == 0:
            arrive("darknet", 1)
        fleet.tick()
    fleet.drain()


def _canary_medians(trace):
    """Pre-drift / pre-swap / post-swap kws canary medians (corrupted
    observations excluded — the runtime's median filter rides over them,
    the summary should too)."""
    drift_tick = trace.of_type("set-condition")[0]["tick"]
    swaps = trace.of_type("swap")
    swap_tick = swaps[0]["tick"] if swaps else None
    eras = {"pre_drift": [], "drifted": [], "post_swap": []}
    for c in trace.of_type("canary"):
        if c["model"] != "kws" or c["corrupted"]:
            continue
        if c["tick"] < drift_tick:
            eras["pre_drift"].append(c["agreement"])
        elif swap_tick is None or c["tick"] < swap_tick:
            eras["drifted"].append(c["agreement"])
        else:
            eras["post_swap"].append(c["agreement"])
    return {k: (round(float(np.median(v)), 4) if v else None)
            for k, v in eras.items()}


def run_demo(*, size: str, out_path: str = "BENCH_fleet.json"):
    trace = trace_mod.Trace()
    config = dict(size=size, seed=SEED, plan=PLAN.to_dict(),
                  drift_condition=[DRIFT_CONDITION.sigma_w,
                                   DRIFT_CONDITION.sigma_a,
                                   DRIFT_CONDITION.sigma_mac])
    fleet = build_fleet(config, trace)
    drive(fleet)

    audits = {name: fleet.audit(name) for name in fleet.models}
    stats = fleet.stats()
    breaches = trace.of_type("breach")
    swaps = trace.of_type("swap")
    retrains = trace.of_type("retrain")
    medians = _canary_medians(trace)

    report = trace_mod.replay(trace, build_fleet)

    doc = {"fleet": {
        "benchmark": "fleet_canary_retrain_hotswap_incident",
        "backend": jax.default_backend(),
        "seed": SEED,
        "size": size,
        "fault_plan": PLAN.to_dict(),
        "slo": {"kws": KWS_SLO.to_dict(), "darknet": DN_SLO.to_dict()},
        "n_events": len(trace),
        "breach_tick": breaches[0]["tick"] if breaches else None,
        "breach_drop": round(breaches[0]["drop"], 4) if breaches else None,
        "swap_tick": swaps[0]["tick"] if swaps else None,
        "retrain_ticks": len(retrains),
        "retrain_final_loss": round(retrains[-1]["loss"], 4)
        if retrains else None,
        "canary_medians_kws": medians,
        "audits": audits,
        "counters": {
            name: {k: stats[name][k] for k in
                   ("served", "shed", "flush_faults", "retries",
                    "stuck_flushes", "generation")}
            for name in fleet.models},
        "replay_bit_exact": report.bit_exact,
        "exactly_once_all": all(a["exactly_once"] for a in audits.values()),
        "within_slo_all": all(a["within_slo"] for a in audits.values()),
        "incident_healed": bool(breaches and swaps
                                and stats["kws"]["state"] == "HEALTHY"),
    }}

    for k in ("breach_tick", "swap_tick", "retrain_ticks",
              "replay_bit_exact", "exactly_once_all", "within_slo_all",
              "incident_healed"):
        print(f"fleet,{k},{doc['fleet'][k]},seeded incident ({size})")
    for name, a in audits.items():
        print(f"fleet,{name}_served,{a['served']},"
              f"of {a['n']} ({a['shed']} shed: {a['shed_codes']})")
    print(f"fleet,canary_medians_kws,{medians},"
          "clean-agreement median per era")
    print(report.summary())
    common.merge_bench_json(out_path, doc)
    print(f"fleet,artifact,{out_path},written")
    return doc


def bench_fleet():
    """benchmarks/run.py --only fleet: the full-size incident."""
    print("# Fleet control plane — fault-injected canary/retrain/hot-swap")
    run_demo(size="full")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dry-run", action="store_true",
                    help="small retrain budget (make bench-fleet)")
    ap.add_argument("--out", default="BENCH_fleet.json")
    args = ap.parse_args(argv)
    run_demo(size="dry" if args.dry_run else "full", out_path=args.out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
