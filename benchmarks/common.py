"""Shared training harness for the paper-table benchmarks.

CPU-scale honesty (DESIGN.md §6): the paper's absolute accuracies need
V100-scale training on the real datasets; these benchmarks reproduce the
paper's *relative* claims at reduced scale on deterministic synthetic data
with matched shapes — GQ rescues low-bit training (Table 1), learned
quantization beats fixed-range (Table 2), FQ ~= Q accuracy after BN removal
(Table 4/6), noise training recovers accuracy (Table 7). Every printed row
is labeled reduced-scale.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import distill as distill_mod
from repro.core.noise import NoiseConfig
from repro.core.quant import QuantConfig
from repro.data import synthetic
from repro.optim import schedules, sgd


@dataclasses.dataclass
class BenchTask:
    """A reduced classification task + model family (resnet/kws/darknet)."""
    net: object                  # PaperNet (configs/paper_nets.py)
    n_train: int = 512
    n_test: int = 256
    batch: int = 64
    steps_per_stage: int = 120
    lr: float = 0.05
    seed: int = 0
    data_noise: float = 2.0   # tuned so FP lands ~0.9: bitwidths separate

    def make_data(self):
        cfg = self.net.reduced
        key = jax.random.key(self.seed)
        k1, k2 = jax.random.split(key)
        shape = self.net.reduced_input_shape
        ncls = self.net.reduced_classes
        if self.net.name == "kws":
            xtr, ytr = synthetic.make_mfcc_dataset(
                k1, n=self.n_train, seq_len=shape[0], n_mfcc=shape[1],
                num_classes=ncls, noise=self.data_noise)
            xte, yte = synthetic.make_mfcc_dataset(
                k2, n=self.n_test, seq_len=shape[0], n_mfcc=shape[1],
                num_classes=ncls, noise=self.data_noise)
        else:
            xtr, ytr = synthetic.make_image_dataset(
                k1, n=self.n_train, shape=shape, num_classes=ncls,
                noise=self.data_noise)
            xte, yte = synthetic.make_image_dataset(
                k2, n=self.n_test, shape=shape, num_classes=ncls,
                noise=self.data_noise)
        return (xtr, ytr), (xte, yte)


def train_stage_fn(task: BenchTask, data, *, noise: Optional[NoiseConfig]
                   = None, distill_alpha: float = 0.7):
    """Builds the gradual-quantization ``train_stage`` callable: trains one
    ladder stage with SGD+Nesterov (paper hyper-params, scaled down) and
    distillation from the running teacher; returns val accuracy."""
    (xtr, ytr), (xte, yte) = data
    module, cfg = task.net.module, task.net.reduced
    nsteps = task.steps_per_stage

    def accuracy(params, state, qcfg):
        logits, _ = module.apply(params, state, xte, qcfg, cfg, train=False)
        return float(jnp.mean(jnp.argmax(logits, -1) == yte))

    def train_stage(bundle, qcfg: QuantConfig, teacher_bundle, stage_idx):
        params, state = bundle
        opt = sgd.make(schedules.cosine(task.lr, nsteps),
                       weight_decay=5e-4)
        ost = opt.init(params)

        def loss_fn(p, st, xb, yb, rng):
            logits, new_st = module.apply(p, st, xb, qcfg, cfg, train=True,
                                          noise=noise, rng=rng)
            onehot = jax.nn.one_hot(yb, cfg.num_classes)
            ce = jnp.mean(distill_mod.softmax_cross_entropy(logits, onehot))
            if teacher_bundle is not None:
                tp, ts = teacher_bundle[0], teacher_bundle[1]
                tq = teacher_bundle[2] if len(teacher_bundle) > 2 else qcfg
                t_logits, _ = module.apply(tp, ts, xb, tq, cfg, train=False)
                ce = distill_mod.distillation_loss(
                    logits, jax.lax.stop_gradient(t_logits), yb,
                    alpha=distill_alpha)
            return ce, new_st

        @jax.jit
        def step(p, st, ost, xb, yb, i, rng):
            (l, new_st), g = jax.value_and_grad(loss_fn, has_aux=True)(
                p, st, xb, yb, rng)
            p, ost = opt.update(p, g, ost, i)
            return p, new_st, ost, l

        n = xtr.shape[0]
        rng = jax.random.key(100 + stage_idx)
        for i in range(nsteps):
            rng, k1, k2 = jax.random.split(rng, 3)
            idx = jax.random.randint(k1, (task.batch,), 0, n)
            params, state, ost, l = step(params, state, ost, xtr[idx],
                                         ytr[idx], jnp.int32(i),
                                         k2 if noise else None)
        acc = accuracy(params, state, qcfg)
        return (params, state), acc

    return train_stage, accuracy


# Keyed cache for the stand-in builder: the serving bench, the noise sweep
# and the slow tests each rebuild the same init-and-fold stacks many times
# (module init + BN fold + conversion per call). Keys are fully value-like
# (module name + frozen dataclass cfg/qcfg + scalars), so a hit is exact.
# Entries are treated as immutable by every caller (jax arrays are; tests
# that tweak a layer copy the dict first).
_STANDIN_CACHE = {}


def trained_int_params(module, cfg, names, qcfg, *, s_out=0.2, seed=0):
    """Init-and-fold integer deployment params with a consistent FQ
    hand-off contract (s_in[i+1] == s_out[i]) — a stand-in for a trained
    checkpoint. The single source of truth for this stand-in logic: the
    serving/noise benchmarks use it directly and tests/conftest.py wraps
    it. Returns (fq_params, state, int_params), cached per key — callers
    must not mutate the returned trees in place."""
    key = (module.__name__, cfg, tuple(names), qcfg, float(s_out), int(seed))
    hit = _STANDIN_CACHE.get(key)
    if hit is not None:
        return hit
    params, state = module.init(jax.random.key(seed), cfg)
    params = module.to_fq(params, state, cfg)
    for n in names:
        params[n]["s_out"] = jnp.float32(s_out)
    for a, b in zip(names, names[1:]):
        params[b]["s_in"] = params[a]["s_out"]
    out = (params, state, module.convert_int(params, state, qcfg, cfg))
    _STANDIN_CACHE[key] = out
    return out


def reduced_int_models(qcfg):
    """Reduced KWS + darknet integer stacks for the serving/noise
    benchmarks: (kws_cfg, kws_ip, dn_cfg, dn_ip)."""
    from repro.models import darknet, kws
    kws_cfg = kws.KWSConfig.reduced()
    _, _, kws_ip = trained_int_params(
        kws, kws_cfg, [f"conv{i}" for i in range(len(kws_cfg.dilations))],
        qcfg)
    dn_cfg = darknet.DarkNetConfig.reduced()
    dn_names = [f"conv{i}" for i in
                range(len([l for l in dn_cfg.layers if l != "M"]))]
    _, _, dn_ip = trained_int_params(darknet, dn_cfg, dn_names, qcfg)
    return kws_cfg, kws_ip, dn_cfg, dn_ip


def timer(fn, *args, reps: int = 3, **kw):
    fn(*args, **kw)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6  # us per call


def merge_bench_json(path: str, updates: dict) -> dict:
    """Update top-level keys of a benchmark JSON artifact in place.

    Several benchmarks share one artifact (serve_cnn and serve_mixed both
    record into BENCH_serve_cnn.json); merging instead of overwriting lets
    them run in any order without clobbering each other's sections. A
    missing or unparseable file starts fresh.
    """
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        doc = {}
    if not isinstance(doc, dict):
        doc = {}
    doc.update(updates)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    return doc
